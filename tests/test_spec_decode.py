"""Speculative decoding on the unified ragged step: oracles, scheduling,
resolution (docs/serving.md).

THE correctness property: greedy token streams with speculation on are
BIT-IDENTICAL to the non-speculative run — drafting/verification may
change how many steps the work takes, never what comes out.  The
self-draft (``draft="self"``) is the acceptance-1.0 oracle: every draft
is the verifier's own greedy continuation, so any stream divergence is a
verify/rollback bug, not a bad draft.  A foreign draft model with random
weights is the opposite fixture — near-zero acceptance exercises the
rejection/rollback path on every step and the streams must STILL match.

Scheduling: a speculating slot costs ``1 + k`` budget rows, priced after
decode grants and before prefill chunks (decode-first order preserved);
``speculation="off"`` leaves the planner byte-identical to the
pre-speculation planner.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from _engine_helpers import make_engine, make_spec
from repro.core.resolve import SpeculationConfig
from repro.models.model import init_params
from repro.serving.draft import ModelDraft, NGramDraft, make_draft
from repro.serving.engine import Request
from repro.serving.scheduler import Scheduler, synthetic_workload

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def smollm():
    cfg = C.get_reduced("smollm-360m")
    return cfg, init_params(KEY, cfg, jnp.float32)


@pytest.fixture(scope="module")
def moe():
    cfg = C.get_reduced("phi3.5-moe-42b")
    return cfg, init_params(KEY, cfg, jnp.float32)


@pytest.fixture(scope="module")
def mla():
    cfg = C.get_reduced("minicpm3-4b")
    return cfg, init_params(KEY, cfg, jnp.float32)


def _streams(cfg, params, speculation, *, kv="dense", n=4, prompt_len=10,
             out=8, batch=2, max_len=64, chunk=8, **kw):
    eng = make_engine(cfg, params, max_batch=batch, max_len=max_len,
                      chunk=chunk, kv=kv, prompt_len=prompt_len,
                      max_new_tokens=out, speculation=speculation, **kw)
    sched = Scheduler(eng)
    for r in synthetic_workload(n, prompt_len=prompt_len,
                                max_new_tokens=out, vocab=cfg.vocab_size):
        sched.submit(r)
    done = sched.run()
    assert len(done) == n
    assert all(len(r.out_tokens) == r.max_new_tokens for r in done)
    return {r.rid: list(r.out_tokens) for r in done}, eng


# ---------------------------------------------------------------------------
# the bit-exactness oracle (self-draft = acceptance-1.0)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 2, 4])
def test_self_draft_streams_bit_identical_gqa(smollm, k):
    cfg, params = smollm
    base, _ = _streams(cfg, params, "off")
    spec, eng = _streams(cfg, params,
                         SpeculationConfig(k=k, draft="self"))
    assert spec == base
    st = eng.spec_stats()
    # greedy self-drafts are the verifier's own continuations: all accepted
    assert st["n_spec_steps"] > 0
    assert st["spec_accept_rate"] == pytest.approx(1.0)
    assert st["spec_tokens_per_step"] > 1.0


def test_self_draft_bit_identical_on_paged_kv(smollm):
    cfg, params = smollm
    base, _ = _streams(cfg, params, "off")
    spec, eng = _streams(cfg, params,
                         SpeculationConfig(k=4, draft="self"), kv="auto")
    assert eng.kv.backend == "paged"
    assert spec == base
    assert eng.spec_stats()["spec_tokens_per_step"] > 1.0


@pytest.mark.parametrize("fixture", ["moe", "mla"])
def test_self_draft_bit_identical_moe_mla(fixture, request):
    """MoE-dropless (count-independent dispatch) and MLA (latent cache)
    verify multi-row slots exactly — dense and paged backends."""
    cfg, params = request.getfixturevalue(fixture)
    kw = dict(n=3, out=6)
    base, _ = _streams(cfg, params, "off", **kw)
    for kv in ("dense", "auto"):
        spec, eng = _streams(cfg, params,
                             SpeculationConfig(k=2, draft="self"),
                             kv=kv, **kw)
        assert spec == base, (fixture, kv)
        assert eng.spec_stats()["n_spec_accepted"] > 0


def test_foreign_draft_rejections_bit_exact(smollm):
    """A reduced-config draft model with random weights proposes garbage
    (near-zero acceptance) — every step exercises rejection + paged-KV
    rollback, and the streams still match the non-speculative run."""
    cfg, params = smollm
    base, _ = _streams(cfg, params, "off")
    sc = SpeculationConfig(k=4, draft="gemma-2b", min_accept=0.0)
    spec, eng = _streams(cfg, params, sc, kv="auto")
    assert spec == base
    assert isinstance(eng.draft, ModelDraft)
    assert eng.draft.cfg.name != cfg.name        # a real foreign model
    st = eng.spec_stats()
    assert st["n_spec_drafted"] > 0
    assert st["n_spec_accepted"] < st["n_spec_drafted"]  # rollbacks fired


def test_ngram_draft_bit_exact(smollm):
    cfg, params = smollm
    base, _ = _streams(cfg, params, "off")
    spec, eng = _streams(cfg, params,
                         SpeculationConfig(k=2, draft="ngram"))
    assert isinstance(eng.draft, NGramDraft)
    assert spec == base


def test_preempt_resume_with_speculation(smollm):
    """Preemption mid-speculation and cache-preserving resume (paged KV)
    still land on the uninterrupted non-speculative stream."""
    cfg, params = smollm
    prompt = np.random.default_rng(5).integers(
        0, cfg.vocab_size, 40).astype(np.int32)
    kw = dict(max_batch=1, max_len=128, chunk=8, kv="auto",
              prompt_len=40, max_new_tokens=8)

    eng = make_engine(cfg, params,
                      speculation=SpeculationConfig(k=2, draft="self"), **kw)
    r = Request(rid=0, prompt=prompt, max_new_tokens=8)
    assert eng.admit(r)
    for _ in range(6):                 # 5 prefill steps + 1 spec decode step
        eng.step()
    assert 1 <= len(r.out_tokens) < 8
    assert eng.preempt(0) is r
    assert eng.admit(r)                # resume re-matches prompt pages
    assert eng.kv.stats.n_prefix_hits == 1
    while not r.done:
        eng.step()

    base = make_engine(cfg, params, speculation="off", **kw)
    r2 = Request(rid=1, prompt=prompt, max_new_tokens=8)
    assert base.admit(r2)
    while not r2.done:
        base.step()
    assert list(r.out_tokens) == list(r2.out_tokens)


def test_shared_prefix_pages_never_written_during_speculation(smollm):
    """Rejected drafts roll a slot's tail back toward the shared-prefix
    boundary — the indexed pages' device bytes must be bit-identical
    before and after a speculating request decodes on top of them."""
    cfg, params = smollm
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab_size, 40).astype(np.int32)
    sc = SpeculationConfig(k=4, draft="gemma-2b", min_accept=0.0)
    eng = make_engine(cfg, params, max_batch=2, max_len=128, chunk=8,
                      kv="auto", prompt_len=40, max_new_tokens=8,
                      speculation=sc)

    sched = Scheduler(eng)
    sched.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
    sched.run()                        # cold run parks prompt pages
    shared = sorted(eng.kv._node_of_page)
    assert shared
    snap = [{k: np.asarray(v)[:, shared] for k, v in g.items()}
            for g in eng.kv.cache["groups"]]

    sched2 = Scheduler(eng)
    sched2.submit(Request(rid=1, prompt=prompt, max_new_tokens=8))
    done = sched2.run()
    assert len(done) == 1
    assert eng.kv.stats.n_prefix_hits == 1
    assert eng.spec_stats()["n_spec_drafted"] > 0      # speculation ran
    for g, s in zip(eng.kv.cache["groups"], snap):
        for name, arr in g.items():
            assert np.array_equal(np.asarray(arr)[:, shared], s[name]), name


# ---------------------------------------------------------------------------
# draft sources (unit)
# ---------------------------------------------------------------------------

def test_ngram_propose_continues_matched_suffix():
    d = NGramDraft(ngram=3)
    ctx = {0: np.asarray([1, 2, 3, 4, 9, 1, 2], np.int64)}
    out = d.propose(ctx, {0: 2})
    assert out[0].tolist() == [3, 4]   # continuation of the earlier [1, 2]


def test_ngram_propose_no_match_is_empty():
    d = NGramDraft(ngram=3)
    assert d.propose({0: np.arange(8, dtype=np.int64)}, {0: 2}) == {}
    assert d.propose({0: np.asarray([1, 2], np.int64)}, {0: 2}) == {}


def test_make_draft_resolves_sources(smollm):
    cfg, params = smollm
    self_d = make_draft(SpeculationConfig(k=2, draft="self"), cfg, params)
    assert isinstance(self_d, ModelDraft) and self_d.cfg is cfg
    with pytest.raises(NotImplementedError):
        make_draft(SpeculationConfig(k=2, draft="mtp"), cfg, params)
    with pytest.raises(KeyError):
        make_draft(SpeculationConfig(k=2, draft="no-such-arch"), cfg,
                   params)


# ---------------------------------------------------------------------------
# budget accounting under speculation
# ---------------------------------------------------------------------------

def _decode_ready(cfg, params, *, speculation, n_req=1, **kw):
    """Engine with ``n_req`` slots past prefill (decoding phase)."""
    eng = make_engine(cfg, params, max_batch=2, max_len=64, chunk=8,
                      prompt_len=10, max_new_tokens=8,
                      speculation=speculation, **kw)
    rng = np.random.default_rng(0)
    for rid in range(n_req):
        p = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
        assert eng.admit(Request(rid=rid, prompt=p, max_new_tokens=8))
    while any(eng._prompt_pos[i] < len(eng._pending[i])
              for i in range(n_req)):
        eng.unified_step(eng.plan_q_lens())
    return eng


def test_speculating_slot_costs_k_plus_1_rows(smollm):
    cfg, params = smollm
    eng = _decode_ready(cfg, params, n_req=2,
                        speculation=SpeculationConfig(k=2, draft="self"))
    # budget 4: both decode rows funded first, then drafts in admission
    # order — slot 0 takes the remaining 2 rows, slot 1 gets none
    q = eng.plan_q_lens(4)
    assert q.tolist() == [3, 1]
    assert eng._drafts[0] is not None and len(eng._drafts[0]) == 2
    assert eng._drafts[1] is None
    # a decode-only budget leaves no draft rows at all
    q = eng.plan_q_lens(2)
    assert q.tolist() == [1, 1]
    assert eng._drafts == [None, None]


def test_drafts_never_starve_prefill(smollm):
    """Draft rows are priced before the prefill loop but the auto budget
    keeps the chunk funded — a waiting prefill still gets rows."""
    cfg, params = smollm
    eng = _decode_ready(cfg, params, n_req=1,
                        speculation=SpeculationConfig(k=4, draft="self"))
    p = np.random.default_rng(1).integers(0, cfg.vocab_size,
                                          10).astype(np.int32)
    assert eng.admit(Request(rid=9, prompt=p, max_new_tokens=4))
    q = eng.plan_q_lens(8)
    assert q[0] == 5                   # 1 decode + 4 draft rows
    assert q[1] == 3                   # the prefill rides the same step
    assert int(q.sum()) == 8


def test_draft_trimmed_by_generation_room(smollm):
    """Full acceptance commits k+1 tokens; the planner never drafts past
    ``max_new_tokens`` (k <= room)."""
    cfg, params = smollm
    eng = make_engine(cfg, params, max_batch=2, max_len=64, chunk=8,
                      prompt_len=10, max_new_tokens=2,
                      speculation=SpeculationConfig(k=4, draft="self"))
    p = np.random.default_rng(2).integers(0, cfg.vocab_size,
                                          10).astype(np.int32)
    assert eng.admit(Request(rid=0, prompt=p, max_new_tokens=2))
    while eng._prompt_pos[0] < len(eng._pending[0]):
        eng.unified_step(eng.plan_q_lens())
    # 1 token out, 1 to go: room = 2 - 1 - 1 = 0 -> no drafting
    q = eng.plan_q_lens()
    assert q.tolist() == [1, 0] and eng._drafts[0] is None


def test_planner_off_is_the_pre_speculation_planner(smollm):
    """speculation="off" resolves to no draft source; the plan is the
    plain decode-first Sarathi schedule, byte for byte."""
    cfg, params = smollm
    eng = _decode_ready(cfg, params, n_req=1, speculation="off")
    assert eng.draft is None and eng.spec_k == 0
    p = np.random.default_rng(3).integers(0, cfg.vocab_size,
                                          10).astype(np.int32)
    assert eng.admit(Request(rid=9, prompt=p, max_new_tokens=4))
    assert eng.plan_q_lens().tolist() == [1, 8]
    assert eng.plan_q_lens(5).tolist() == [1, 4]
    assert eng.spec_stats()["n_spec_steps"] == 0


def test_acceptance_ema_gates_drafting(smollm):
    """A draft whose proposals keep getting rejected drives the EMA under
    the gate — the planner stops paying for drafts (except probes)."""
    cfg, params = smollm
    sc = SpeculationConfig(k=4, draft="gemma-2b", min_accept=0.9,
                           ema_alpha=0.5, probe_every=1000)
    base, _ = _streams(cfg, params, "off", n=3, out=12)
    spec, eng = _streams(cfg, params, sc, n=3, out=12)
    assert spec == base
    assert eng.accept_ema < sc.min_accept
    st = eng.spec_stats()
    # gated off after the first rejections: far fewer drafted rows than
    # the ungated 4-per-slot-step worst case
    assert 0 < st["n_spec_drafted"] < 4 * 3 * 12


def test_no_starvation_under_poisson_load_with_spec(smollm):
    from repro.serving.scheduler import mixed_workload

    cfg, params = smollm
    eng = make_engine(cfg, params, max_batch=2, max_len=96, chunk=8,
                      prompt_len=48, max_new_tokens=5,
                      speculation=SpeculationConfig(k=2, draft="self"))
    sched = Scheduler(eng)
    reqs = list(mixed_workload(6, short_len=10, n_long=2, long_len=48,
                               max_new_tokens=5, vocab=cfg.vocab_size,
                               arrival_rate=32.0, seed=3))
    for r in reqs:
        sched.submit(r)
    done = sched.run()
    assert len(done) == len(reqs)
    assert all(len(r.out_tokens) == r.max_new_tokens for r in done)
    m = sched.metrics()
    assert m.n_incomplete == 0
    assert m.n_spec_steps > 0          # counters surface in ServeMetrics
    assert m.spec_tokens_per_step > 1.0
    assert "spec=" in m.row()


# ---------------------------------------------------------------------------
# resolution (ServeSpec.speculation -> core.resolve.auto_speculation)
# ---------------------------------------------------------------------------

def test_explicit_k_clamps_to_chunk(smollm):
    cfg, _ = smollm
    spec = make_spec(cfg, chunk=4, speculation=8)
    assert spec.speculation.k == 3     # k + 1 rows must fit the chunk
    assert "speculation" in spec.provenance
    assert "explicit" in spec.provenance["speculation"]
    assert "k=3" in spec.describe()


def test_auto_speculation_prices_decode_heavy(smollm):
    cfg, _ = smollm
    spec = make_spec(cfg, chunk=8, speculation="auto", prompt_len=8,
                     max_new_tokens=24)
    assert spec.speculation is not None and spec.speculation.k >= 1
    assert spec.provenance["speculation"].startswith("auto:cost-model")
    assert "tok/step" in spec.provenance["speculation"]
    meta = spec.as_meta()
    assert "k=" in meta["resolved"]["speculation"]


def test_off_resolves_to_none(smollm):
    cfg, _ = smollm
    spec = make_spec(cfg, speculation="off")
    assert spec.speculation is None
    assert spec.as_meta()["resolved"]["speculation"] == "off"


def test_sampling_temperature_rejects_speculation(smollm):
    cfg, _ = smollm
    with pytest.raises(ValueError, match="greedy"):
        make_spec(cfg, speculation=2, temperature=0.8)
    spec = make_spec(cfg, speculation="auto", temperature=0.8)
    assert spec.speculation is None    # auto degrades to off


def test_legacy_family_rejects_speculation():
    cfg = C.get_reduced("recurrentgemma-9b")
    with pytest.raises(ValueError, match="unified"):
        make_spec(cfg, speculation=2)
    spec = make_spec(cfg, speculation="auto")
    assert spec.speculation is None


def test_speculation_config_validation():
    with pytest.raises(ValueError):
        SpeculationConfig(k=0)
    with pytest.raises(ValueError):
        SpeculationConfig(k=2, min_accept=1.5)
    with pytest.raises(ValueError):
        SpeculationConfig(k=2, ema_alpha=0.0)
    sc = SpeculationConfig(k=3, draft="self")
    assert "k=3" in sc.describe() and "self" in sc.describe()


def test_auto_token_budget_funds_verify_rows(smollm):
    cfg, _ = smollm
    spec = make_spec(cfg, chunk=8, max_batch=4, token_budget="auto",
                     speculation=SpeculationConfig(k=4, draft="ngram"))
    assert spec.token_budget == 4 * 5 + 8      # slots x (1+k) + chunk
    assert "k=4" in spec.provenance["token_budget"]
