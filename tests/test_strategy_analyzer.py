"""Strategy grammar enumeration + automatic analyzer behaviour."""

import pytest

from repro.configs import get
from repro.core import analyzer
from repro.core.cost_model import Strategy
from repro.core.strategy import PRESETS, enumerate_strategies, preset
from repro.core.topology import (ASCEND_910B_CLUSTER, H20_CLUSTER,
                                 TPU_V5E_POD)


def test_grammar_covers_cluster():
    for cl in (H20_CLUSTER, ASCEND_910B_CLUSTER):
        for moe in (True, False):
            strats = list(enumerate_strategies(cl, model_is_moe=moe))
            assert strats
            for s in strats:
                s.validate()
                assert s.n_devices == cl.n_devices


def test_grammar_degrees_are_pow2():
    for s in enumerate_strategies(H20_CLUSTER, model_is_moe=True):
        for d in (s.attn_tp, s.attn_dp, s.moe_tp, s.moe_ep, s.d_pp):
            assert d & (d - 1) == 0


def test_presets_match_table2():
    cl = ASCEND_910B_CLUSTER             # 4 nodes x 8 NPUs
    s = preset("vllm_tp_pp", cl)
    assert (s.attn_tp, s.d_pp) == (8, 4)
    s = preset("vllm_dp_ep", cl)
    assert (s.attn_tp, s.attn_dp, s.moe_ep) == (8, 4, 32)
    s = preset("tutel_tp_ep", cl)
    assert (s.moe_tp, s.moe_ep) == (8, 4)
    s = preset("mixserve", cl)
    assert (s.moe_tp, s.moe_ep, s.comm_algo) == (8, 4, "fused")
    for name in PRESETS:
        preset(name, cl).validate()


def test_analyzer_returns_feasible_best():
    model = get("phi3.5-moe-42b")
    rep = analyzer.select(model, H20_CLUSTER, batch=16, l_in=1024, l_out=128)
    assert rep.best.feasible
    assert rep.best.ind.stable
    # ranked by the objective
    scores = [c.score(rep.objective) for c in rep.ranked]
    assert scores == sorted(scores)


def test_analyzer_prefers_hybrid_for_deepseek_on_910b():
    """The paper's headline result: for DeepSeek-R1-class models on the 910B
    cluster the hybrid TP-EP fused strategy wins over pure EP and TP+PP."""
    model = get("deepseek-v2-236b")
    rep = analyzer.select(model, ASCEND_910B_CLUSTER, batch=16, l_in=1024,
                          l_out=128, objective="throughput")
    best = rep.best.strategy
    assert best.moe_tp > 1 and best.moe_ep > 1, best.describe()
    assert best.comm_algo == "fused"


def test_analyzer_respects_memory():
    model = get("deepseek-v2-236b")
    rep = analyzer.select(model, TPU_V5E_POD, batch=16, l_in=1024, l_out=128)
    for c in rep.ranked:
        if c.feasible:
            assert c.mem_bytes < TPU_V5E_POD.hbm_bytes


def test_analyzer_expert_divisibility():
    model = get("phi3.5-moe-42b")        # 16 experts
    rep = analyzer.select(model, TPU_V5E_POD, batch=16, l_in=512, l_out=64)
    # EP degree beyond n_experts is infeasible
    for c in rep.ranked:
        if c.strategy.moe_ep > 16:
            assert not c.feasible


def test_fused_dominates_unfused_when_ep_inter_node():
    """The paper's regime: with the EP group spanning nodes, fused RS-A2A-AG
    must not lose to the unfused layout.  (When EP fits INSIDE a node the
    reorganization's extra intra RS/AG is pure overhead and the analyzer
    correctly prefers unfused — deliberately NOT asserted here.)"""
    model = get("deepseek-v2-236b")
    rep = analyzer.select(model, ASCEND_910B_CLUSTER, batch=16, l_in=1024,
                          l_out=128, comm_algos=("fused", "unfused"))
    by_layout = {}
    for c in rep.ranked:
        s = c.strategy
        key = (s.attn_tp, s.attn_dp, s.moe_tp, s.moe_ep, s.d_pp,
               s.ep_inter_node)
        by_layout.setdefault(key, {})[s.comm_algo] = c.ind.itl
    checked = 0
    for key, d in by_layout.items():
        if ("fused" in d and "unfused" in d and 1 < key[2] and key[3] > 1
                and key[5]                         # ep_inter_node
                and key[2] <= ASCEND_910B_CLUSTER.n_proc):  # TP intra-node
            assert d["fused"] <= d["unfused"] * 1.0001, (key, d)
            checked += 1
    assert checked > 0
