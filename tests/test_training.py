"""Training substrate: optimizer math, checkpoint roundtrip, loss descent."""

import os

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.model import init_params
from repro.training import checkpoint
from repro.training.optimizer import (AdamWConfig, adamw_update, cosine_lr,
                                      init_opt_state)
from repro.training.train_step import cross_entropy, make_train_step


def test_adamw_first_step_matches_manual():
    cfg = AdamWConfig(lr=0.1, beta1=0.9, beta2=0.999, eps=1e-8,
                      weight_decay=0.0, warmup_steps=0, total_steps=10,
                      min_lr_ratio=1.0, grad_clip=0.0)
    p = {"w": jnp.asarray([1.0, 2.0])}
    g = {"w": jnp.asarray([0.5, -0.5])}
    st = init_opt_state(p)
    new_p, new_st, stats = adamw_update(cfg, g, st, p)
    # first AdamW step with bias correction moves by exactly lr * sign(g)
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               [1.0 - 0.1, 2.0 + 0.1], atol=1e-5)
    assert int(new_st.step) == 1


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_ratio=0.1)
    assert float(cosine_lr(cfg, 0)) == 0.0
    assert float(cosine_lr(cfg, 10)) == 1.0
    assert abs(float(cosine_lr(cfg, 110)) - 0.1) < 1e-6
    assert float(cosine_lr(cfg, 60)) < 1.0


def test_grad_clip():
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=0, total_steps=1,
                      min_lr_ratio=1.0, weight_decay=0.0)
    p = {"w": jnp.zeros(3)}
    g = {"w": jnp.asarray([30.0, 40.0, 0.0])}   # norm 50 -> scaled by 1/50
    _, _, stats = adamw_update(cfg, g, init_opt_state(p), p)
    assert abs(float(stats["grad_norm"]) - 50.0) < 1e-4


def test_cross_entropy_one_hot_equals_gather():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (2, 5, 17))
    labels = jax.random.randint(key, (2, 5), 0, 17)
    got = cross_entropy(logits, labels)
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    want = (lse - gold).mean()
    np.testing.assert_allclose(float(got), float(want), rtol=1e-6)


def test_loss_decreases_100m_scale():
    """Train a ~1M-param reduced model for 30 steps; loss must fall."""
    cfg = C.get_reduced("smollm-360m")
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(
        cfg, opt_cfg=AdamWConfig(lr=2e-3, warmup_steps=3, total_steps=30)))
    data = SyntheticLM(cfg, DataConfig(batch=8, seq_len=64, seed=0))
    losses = []
    for batch in data.batches(30):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_checkpoint_roundtrip(tmp_path):
    cfg = C.get_reduced("gemma-2b")
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    path = os.path.join(tmp_path, "ckpt.npz")
    checkpoint.save(path, {"params": params, "step": jnp.asarray(7)})
    like = {"params": params, "step": jnp.asarray(0)}
    restored = checkpoint.restore(path, like)
    assert int(restored["step"]) == 7
    for a, b in zip(jax.tree.leaves(params),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_deterministic():
    cfg = C.get_reduced("smollm-360m")
    d1 = SyntheticLM(cfg, DataConfig(batch=2, seq_len=32, seed=5))
    d2 = SyntheticLM(cfg, DataConfig(batch=2, seq_len=32, seed=5))
    b1 = next(iter(d1.batches(1)))
    b2 = next(iter(d2.batches(1)))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    d3 = SyntheticLM(cfg, DataConfig(batch=2, seq_len=32, seed=6))
    b3 = next(iter(d3.batches(1)))
    assert not np.array_equal(b1["tokens"], b3["tokens"])
