"""Unified mixed prefill-decode step: numerics, scheduling, and guards.

The correctness contract of the token-budget engine:
  1. a decode-only unified step produces the same logits as the old
     dedicated decode program (same math, per-slot masks);
  2. a prompt streamed through the engine in ragged chunks produces the
     one-shot-prefill logits per slot (the test_chunked_prefill oracle
     pattern, via the ENGINE's jitted program);
  3. a slot's generation is unperturbed by a neighbour prefilling a long
     prompt in the same (B, chunk) buffer — the mixed-batch property that
     dropless MoE dispatch guarantees at the MoE level and per-slot masks
     guarantee at the attention level;
  4. every admitted request finishes under a seeded Poisson workload
     (no starvation), and max_steps exits report the stragglers.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import model as M
from _engine_helpers import make_engine
from repro.serving.engine import Engine, PromptTooLongError, Request
from repro.serving.scheduler import Scheduler, mixed_workload, \
    synthetic_workload

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def smollm():
    cfg = C.get_reduced("smollm-360m")
    params = M.init_params(KEY, cfg, jnp.float32)
    return cfg, params


def _drive_prefill(eng, req, *, budget=None):
    """Admit and run unified steps until the prompt is consumed, collecting
    the per-step (B, chunk, V) logits when the engine keeps them
    (``debug_logits=True``)."""
    assert eng.admit(req)
    step_logits = []
    while eng._prompt_pos[0] < len(req.prompt):
        q = eng.plan_q_lens(budget)
        eng.unified_step(q)
        if eng.debug_logits:
            step_logits.append((np.asarray(q), np.asarray(eng.step_logits)))
    return step_logits


def test_unified_decode_only_matches_decode_program(smollm):
    """After prefill, pure-decode unified steps == the dedicated
    single-token decode program's tokens, step by step (the math the
    retired legacy engine ran — now oracled directly via ``forward``)."""
    cfg, params = smollm
    prompt = np.asarray([3, 1, 4, 1, 5, 9, 2, 6], np.int32)

    # dense KV: the decode-program oracle below drives forward() on a raw
    # snapshot of the engine cache (the paged twin of this oracle is
    # tests/test_paged_engine.py's stream-identity test)
    uni = make_engine(cfg, params, max_batch=2, max_len=64, chunk=8,
                      kv="dense")
    r_u = Request(rid=0, prompt=prompt, max_new_tokens=6)
    _drive_prefill(uni, r_u)   # first token sampled from the last chunk

    # oracle: the old decode program — one-token forward per step on a
    # snapshot of the post-prefill cache (slot 1 is empty; only slot 0's
    # logits are read, and per-slot cache rows cannot interact)
    cache = jax.tree.map(lambda x: x, uni.cache)
    tok = r_u.out_tokens[0]
    oracle = [tok]
    for _ in range(5):
        out = M.forward(params, cfg,
                        tokens=jnp.asarray([[tok], [0]], jnp.int32),
                        cache=cache)
        cache = out.cache
        tok = int(jnp.argmax(out.logits[0, 0]))
        oracle.append(tok)

    while uni.n_active:
        q = uni.plan_q_lens()
        assert q.tolist() == [1, 0]       # decode-only iterations from here
        uni.unified_step(q)
    assert r_u.out_tokens == oracle


@pytest.mark.parametrize("arch", ["smollm-360m", "phi3.5-moe-42b",
                                  "minicpm3-4b"])
def test_engine_chunked_prefill_matches_oneshot_logits(arch):
    """Prompt streamed through the ENGINE in ragged chunks reproduces the
    one-shot prefill logits row-for-row (GQA, MoE-dropless, MLA)."""
    cfg = C.get_reduced(arch)
    params = M.init_params(KEY, cfg, jnp.float32)
    prompt = np.asarray(jax.random.randint(KEY, (11,), 0, cfg.vocab_size),
                        np.int32)
    one = M.forward(params, cfg, tokens=jnp.asarray(prompt)[None],
                    cache=M.init_cache(cfg, 1, 64, jnp.float32))

    eng = make_engine(cfg, params, max_batch=2, max_len=64, chunk=4,
                 debug_logits=True)
    steps = _drive_prefill(eng, Request(rid=0, prompt=prompt,
                                        max_new_tokens=4))
    got = np.concatenate([logits[0, :q[0]] for q, logits in steps], axis=0)
    err = float(np.max(np.abs(got - np.asarray(one.logits[0]))))
    assert err < 2e-4, (arch, err)
    # and the first sampled token is the oracle's argmax
    assert eng._last_tok[0] == int(jnp.argmax(one.logits[0, -1]))


@pytest.mark.parametrize("arch", ["smollm-360m", "phi3.5-moe-42b"])
def test_decode_unperturbed_by_neighbour_prefill(arch):
    """THE mixed-batch property: slot 0's decode logits are identical
    whether slot 1 is idle or prefilling a long prompt in the same step."""
    cfg = C.get_reduced(arch)
    params = M.init_params(KEY, cfg, jnp.float32)
    rng = np.random.default_rng(0)
    p0 = rng.integers(0, cfg.vocab_size, size=9).astype(np.int32)
    p1 = rng.integers(0, cfg.vocab_size, size=24).astype(np.int32)

    def run(with_neighbour: bool):
        eng = make_engine(cfg, params, max_batch=2, max_len=64, chunk=8)
        r0 = Request(rid=0, prompt=p0, max_new_tokens=5)
        _drive_prefill(eng, r0)
        if with_neighbour:
            assert eng.admit(Request(rid=1, prompt=p1, max_new_tokens=2))
        logits = []
        while not r0.done:
            eng.unified_step(eng.plan_q_lens())
            logits.append(np.asarray(eng.last_logits)[0])
        return r0.out_tokens, np.stack(logits)

    toks_alone, log_alone = run(False)
    toks_mixed, log_mixed = run(True)
    assert toks_mixed == toks_alone
    err = float(np.max(np.abs(log_mixed - log_alone)))
    # identical per-slot math; MoE dropless dispatch is count-independent
    assert err < 2e-5, (arch, err)


def test_no_starvation_under_poisson_load(smollm):
    """Every admitted request finishes: long prompts chunk through without
    starving decodes, short ones aren't starved by the long ones."""
    cfg, params = smollm
    eng = make_engine(cfg, params, max_batch=2, max_len=96, chunk=8)
    sched = Scheduler(eng)
    reqs = list(mixed_workload(6, short_len=10, n_long=2, long_len=48,
                               max_new_tokens=5, vocab=cfg.vocab_size,
                               arrival_rate=32.0, seed=3))
    for r in reqs:
        sched.submit(r)
    done = sched.run()
    assert len(done) == len(reqs)
    assert all(len(r.out_tokens) == r.max_new_tokens for r in done)
    m = sched.metrics()
    assert m.n_incomplete == 0 and m.n_requests == len(reqs)
    # TTFT is measured at first-token (after chunked prefill), not admission
    assert all(r.t_first_token >= r.t_admitted for r in done)


def test_max_steps_reports_incomplete(smollm):
    """max_steps exits surface in-flight work instead of dropping it, and
    metrics() is well-defined with zero finished requests."""
    cfg, params = smollm
    eng = make_engine(cfg, params, max_batch=2, max_len=96, chunk=4)
    sched = Scheduler(eng)
    for r in synthetic_workload(4, prompt_len=16, max_new_tokens=8,
                                vocab=cfg.vocab_size):
        sched.submit(r)
    done = sched.run(max_steps=3)
    m = sched.metrics()
    assert m.n_incomplete == 4 - len(done) > 0
    assert np.isfinite(m.ttft_mean) and np.isfinite(m.throughput_tok_s)
    assert m.wall_time > 0


def test_prompt_overflow_rejected(smollm):
    """Silent prompt overflow is gone: an impossible request raises at
    submit/admit."""
    cfg, params = smollm
    eng = make_engine(cfg, params, max_batch=1, max_len=32)
    bad = Request(rid=0, prompt=np.zeros(40, np.int32), max_new_tokens=4)
    with pytest.raises(PromptTooLongError):
        eng.admit(bad)
    with pytest.raises(PromptTooLongError):
        Scheduler(eng).submit(bad)
    # the boundary case still fits: prompt + max_new - 1 == max_len
    ok = Request(rid=1, prompt=np.zeros(29, np.int32), max_new_tokens=4)
    eng.validate(ok)


def test_prompt_overflow_rejected_on_legacy_fallback():
    """The internal blocking-prefill fallback (recurrent families) validates
    the BUCKET, not just the prompt."""
    cfg = C.get_reduced("rwkv6-1.6b")
    params = M.init_params(KEY, cfg, jnp.float32)
    eng = make_engine(cfg, params, max_batch=1, max_len=24)
    assert eng.legacy       # auto-fallback: ssm family
    # a 20-token prompt + 2 new tokens fits 24 cache positions, but the
    # blocking prefill writes the whole 32-wide bucket — rejected
    bad = Request(rid=0, prompt=np.zeros(20, np.int32), max_new_tokens=2)
    with pytest.raises(PromptTooLongError):
        eng.admit(bad)


def test_token_budget_caps_prefill(smollm):
    """A sub-default budget throttles prefill chunks but never decode."""
    cfg, params = smollm
    eng = make_engine(cfg, params, max_batch=3, max_len=96, chunk=8)
    # slot 0 decoding, slots 1-2 prefilling
    r0 = Request(rid=0, prompt=np.arange(6, dtype=np.int32), max_new_tokens=8)
    _drive_prefill(eng, r0)
    assert eng.admit(Request(rid=1, prompt=np.arange(20, dtype=np.int32)
                             % cfg.vocab_size, max_new_tokens=2))
    assert eng.admit(Request(rid=2, prompt=np.arange(20, dtype=np.int32)
                             % cfg.vocab_size, max_new_tokens=2))
    q = eng.plan_q_lens(6)
    assert q[0] == 1                       # decode-first, always scheduled
    assert q[1] == 5 and q[2] == 0         # remaining budget, FIFO order
    q = eng.plan_q_lens()                  # default budget = B * chunk
    assert q[0] == 1 and q[1] == 8 and q[2] == 8


def test_unified_auto_fallback_for_recurrent_family(smollm):
    """ssm/hybrid/frontend archs auto-fall back to the internal legacy
    path; the public escape hatch is retired — the ``legacy=`` kwarg is
    gone and ``REPRO_LEGACY_ENGINE`` is ignored."""
    cfg = C.get_reduced("rwkv6-1.6b")
    params = M.init_params(KEY, cfg, jnp.float32)
    assert make_engine(cfg, params, max_batch=1, max_len=32).legacy
    cfg_s, params_s = smollm
    with pytest.raises(TypeError):
        Engine(cfg_s, params_s, legacy=True)


def test_legacy_env_escape_hatch_retired(smollm, monkeypatch):
    cfg, params = smollm
    monkeypatch.setenv("REPRO_LEGACY_ENGINE", "1")
    assert not make_engine(cfg, params, max_batch=1, max_len=32).legacy


def test_engine_chunked_prefill_flash_chunk_kernel(smollm):
    """The unified engine with KernelPolicy.all_on() runs the ragged
    flash_chunk kernel (traced, counter > 0) and still reproduces the
    one-shot prefill logits and the jnp engine's tokens."""
    from repro.kernels import ops
    from repro.kernels.policy import KernelPolicy

    cfg, params = smollm
    prompt = np.asarray(jax.random.randint(KEY, (11,), 0, cfg.vocab_size),
                        np.int32)
    one = M.forward(params, cfg, tokens=jnp.asarray(prompt)[None],
                    cache=M.init_cache(cfg, 1, 64, jnp.float32))

    def run(policy):
        # dense KV: this asserts the flash_chunk counter specifically (the
        # paged engine traces flash_chunk_paged — covered in
        # tests/test_paged_engine.py's kernel-policy test)
        eng = make_engine(cfg, params, max_batch=2, max_len=64, chunk=4,
                     kernels=policy, debug_logits=True, kv="dense")
        req = Request(rid=0, prompt=prompt, max_new_tokens=3)
        steps = _drive_prefill(eng, req)
        while eng.n_active:
            eng.unified_step(eng.plan_q_lens())
        return req.out_tokens, steps

    base_toks, _ = run(KernelPolicy.off())
    ops.reset_counters()
    kern_toks, steps = run(KernelPolicy.all_on())
    assert ops.counters["flash_chunk"] > 0, dict(ops.counters)
    assert kern_toks == base_toks
    got = np.concatenate([logits[0, :q[0]] for q, logits in steps], axis=0)
    err = float(np.max(np.abs(got - np.asarray(one.logits[0]))))
    assert err < 2e-4, err
