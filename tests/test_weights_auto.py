"""Weight loader roundtrip + automatic plan selection."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.configs.base import INPUT_SHAPES
from repro.models.model import forward, init_params
from repro.serving.weights import export_llama_style, load_llama_style


@pytest.mark.parametrize("arch", ["smollm-360m", "minitron-8b"])
def test_hf_roundtrip_preserves_forward(arch):
    cfg = C.get_reduced(arch)
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    flat = export_llama_style(params, cfg)
    # HF-style names present
    assert "model.embed_tokens.weight" in flat
    assert "model.layers.0.self_attn.q_proj.weight" in flat
    assert "model.layers.1.mlp.down_proj.weight" in flat
    # q_proj is (out, in)-major
    assert flat["model.layers.0.self_attn.q_proj.weight"].shape == \
        (cfg.n_heads * cfg.head_dim, cfg.d_model)

    restored = load_llama_style(flat, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    a = forward(params, cfg, tokens=toks).logits
    b = forward(restored, cfg, tokens=toks).logits
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_loader_rejects_unsupported_family():
    cfg = C.get_reduced("deepseek-v2-236b")
    with pytest.raises(AssertionError):
        export_llama_style({}, cfg)


def test_auto_plan_selects_feasible_layouts():
    """The analyzer-driven plan must be constructible for every arch/shape,
    and must fall back to the hybrid layout when pure-EP cannot divide."""
    import os
    import subprocess
    import sys
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys; sys.path.insert(0, "src")
import repro.configs as C
from repro.configs.base import INPUT_SHAPES
from repro.launch.auto import auto_plan
from repro.launch.mesh import make_production_mesh
mesh = make_production_mesh()
for arch in ("deepseek-v2-236b", "phi3.5-moe-42b", "gemma-2b"):
    cfg = C.get(arch)
    for shape in ("decode_32k", "prefill_32k"):
        plan, rep = auto_plan(cfg, mesh, INPUT_SHAPES[shape])
        assert plan.enabled
        if cfg.is_moe and cfg.n_experts % 256:
            assert plan.rules["expert"] == ("data",), (arch, shape)
        print(arch, shape, rep.best.strategy.describe())
print("AUTO_PLAN_OK")
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=600, env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert "AUTO_PLAN_OK" in r.stdout, r.stdout[-1000:] + r.stderr[-2000:]
